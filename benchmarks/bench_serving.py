"""Serving benchmark: static lock-step cascade vs continuous batching
(slot and block-paged KV backends) on the same synthetic request stream.

Scenarios (same models, same calibrated tau, same prompts):
  * static            — batches of `slots` requests, each decoded for the
                        full `max_new` on M_S before the deferral decision
                        (uniform workloads only)
  * continuous        — slot pool + FIFO admission, early exit disabled
                        (pure scheduling comparison / parity path)
  * continuous+exit   — in-flight deferral: requests whose running mean
                        confidence drops below tau are evicted early,
                        freeing their slot for the next arrival
  * paged[+exit]      — (--backend paged) the same engine over the
                        block-paged cache with chunked prefill (batched
                        same-offset dispatch by default, --serial-prefill
                        for the old one-request-per-iteration loop;
                        --paged-kernel routes decode through the Pallas
                        paged flash-decode kernels); reported with its
                        cache footprint next to the slot pool's so the
                        memory win on ragged traffic is visible
  * continuous+thread — in-flight deferral with the THREADED M_L backend:
                        deferrals stream to a worker thread that batches
                        (large_batch rows or --large-max-wait seconds)
                        and regenerates them while M_S keeps decoding;
                        compare its tokens/s, p95 latency, and deferral
                        wait against continuous+exit (sync M_L inline)
  * continuous+3tier — 3-tier cascade ladder (small -> mid -> large,
                        `CascadeSpec`): per-edge calibrated taus, edge-0
                        deferrals become edge-1 arrival traffic; the row
                        carries tier_served / per-edge deferrals / taus
  * continuous+recal — online tau recalibration: the edge boots with a
                        deliberately stale (0.8-quantile) tau and the
                        EWMA quantile controller walks it toward the
                        target ratio; the tau trace lands in --bench-out
  * continuous+socket — the distributed M_L tier (serving.remote): the
                        same engine config as continuous+thread but
                        deferrals cross a real localhost socket to one
                        `MLServer` replica, under Poisson arrivals at
                        --socket-rate req/s. Each replica injects
                        --socket-ml-latency seconds of per-batch
                        service time (the remote accelerator's service
                        model — a CPU CI box cannot parallelize real
                        M_L compute across replicas, so without it the
                        1-vs-2 comparison would measure single-core
                        contention instead of queueing)
  * continuous+pool2  — same, behind a 2-replica `ReplicaPool` (health
                        checks + batch-aware load balancing); its
                        deferral-wait p95 against continuous+socket is
                        the headline 1-vs-N-replica number: one replica
                        serializes batches through the service latency,
                        two overlap it
  * paged+oversub     — (--backend paged) block pressure handling on a
                        shared-prefix workload where reservation
                        admission is pessimistic (every request reserves
                        its full footprint; physically most of it is
                        shared): a TIGHT budget (worst-case concurrent
                        reservation demand >= 1.5x the blocks, sized one
                        block short of the true peak), oversubscribed
                        with the preempt policy + host swap tier;
                        reports the max sustained arrival rate
                        (completion rate at saturation) against a
                        same-budget reservation-only reference run —
                        which serializes admission — plus preemption /
                        OOM-deferral / swap counts
  * paged+shed        — same tight budget with the shed policy: fast
                        failure instead of preemption (rejected count)

Ragged mode (--ragged-min/--ragged-max) draws mixed prompt lengths from
a uniform distribution and sizes the paged budget for the MEAN request,
not the worst case — the regime the slot backend cannot fit (every slot
reserves max_prompt + max_new) and the static engine cannot serve at
all (lock-step batches need one shape).

Each scenario is run once untimed (compile warm-up; in-process runs are
deterministic, so the warm-up covers every jit shape the timed run needs)
and once timed. Reported per scenario: tokens/s, latency percentiles
(p50/p95/p99), deferral ratio + wait, M_S decode steps executed/saved,
cache footprint.

Observability (`--obs-row`, or implied by any obs output flag): adds a
`continuous+obs` row — the `continuous` configuration re-run with the
observability layer on (span tracing when --trace-out, Prometheus
metrics, bounded event retention) — and gates it within `--obs-gate`
(default 5%) tokens/s of the plain `continuous` row, so instrumentation
overhead is a CI-checked number, not a hope. `--trace-out` dumps the
obs row's Chrome trace (Perfetto-loadable), `--metrics-out` its final
Prometheus scrape.

CI regression gating: `--bench-out BENCH_serving.json` emits the rows as
a machine-readable artifact (tokens/s, p95, deferral, queueing p95 and
the per-phase time breakdown per row);
`--baseline benchmarks/baselines/serving_cpu.json`
fails the run (exit 1) when any row's tokens/s drops more than 25% below
the committed baseline; `--update-baseline` rewrites the baseline file
from the current run (commit it when a slowdown/speedup is intentional).

    PYTHONPATH=src python -m benchmarks.bench_serving
    PYTHONPATH=src python -m benchmarks.bench_serving --backend paged \
        --ragged-min 8 --ragged-max 48 --rate 100
    PYTHONPATH=src python -m benchmarks.bench_serving --requests 12 \
        --max-new 12 --slots 4 --bench-out BENCH_serving.json \
        --baseline benchmarks/baselines/serving_cpu.json
    PYTHONPATH=src python -m benchmarks.bench_serving --obs-row \
        --trace-out /tmp/serving_trace.json
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.calibration import calibrate_edges
from repro.data.synthetic import make_lm_stream, make_ragged_lm_stream
from repro.launch.serve import build_ladder, build_runners
from repro.serving import (CascadeEngine, CascadeSpec, CascadeTier,
                           ContinuousCascadeEngine, DeferralEdge,
                           EngineConfig, MLBackendConfig, PagedConfig,
                           PressureConfig, RecalibConfig, make_requests,
                           poisson_arrivals)
from repro.serving.obs import (ObsConfig, add_obs_args,
                               obs_config_from_args)

from benchmarks.common import emit_csv_row, save_result


def run_static(engine: CascadeEngine, requests: List, prompt_len: int,
               max_new: int, batch_size: int) -> Dict:
    """Lock-step serving under the arrival trace: wait until `batch_size`
    requests have arrived, serve them for the full max_new, repeat."""
    order = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    t0 = time.perf_counter()
    lat, n_deferred = [], 0
    i = 0
    steps = 0
    while i < len(order):
        batch = order[i:i + batch_size]
        while time.perf_counter() - t0 < batch[-1].arrival_time:
            time.sleep(1e-4)
        prompts = np.stack([r.prompt for r in batch])
        res = engine.serve(prompts, prompt_len, max_new)
        now = time.perf_counter() - t0
        lat.extend(now - r.arrival_time for r in batch)
        n_deferred += int(res.deferred.sum())
        steps += max_new - 1
        i += len(batch)
    makespan = time.perf_counter() - t0
    lat = np.array(lat)
    n = len(order)
    return {
        "engine": "static",
        "makespan_s": makespan,
        "throughput_tok_s": n * max_new / makespan,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p95_s": float(np.percentile(lat, 95)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "deferral_ratio": n_deferred / n,
        "deferral_wait_p50_ms": float("nan"),
        "deferral_wait_p95_ms": float("nan"),
        "ms_steps": steps,
        "saved_steps": 0,
        "cache_mb": float("nan"),
    }


def run_continuous(engine: ContinuousCascadeEngine, requests: List,
                   max_new: int, label: str,
                   obs: Optional[ObsConfig] = None) -> Dict:
    res = engine.run(requests, max_new, obs=obs)
    s = res.stats
    row = {
        "engine": label,
        "makespan_s": s["makespan_s"],
        "throughput_tok_s": s["throughput_tok_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "latency_p99_s": s["latency_p99_s"],
        "queueing_p95_s": s.get("queueing_p95_s", float("nan")),
        "deferral_ratio": s["deferral_ratio"],
        "deferral_wait_p50_ms": s.get("deferral_wait_p50_ms",
                                      float("nan")),
        "deferral_wait_p95_ms": s.get("deferral_wait_p95_ms",
                                      float("nan")),
        "ms_steps": res.steps,
        "saved_steps": res.saved_steps,
        "cache_mb": s["cache_bytes"] / 2**20,
    }
    for k, v in s.items():
        if k.startswith("phase_"):
            row[k] = v
    if s.get("n_tiers", 2) > 2:
        row["tier_served"] = s["tier_served"]
        row["edge_deferrals"] = s["edge_deferrals"]
        row["edge_tau"] = s["edge_tau"]
    if "recalibration" in s:
        row["recalibration"] = s["recalibration"]
    if "peak_blocks" in s:
        row["peak_blocks"] = s["peak_blocks"]
        row["n_blocks"] = s["n_blocks"]
        row["prefill_dispatches"] = s["prefill_dispatches"]
        row["prefill_chunks"] = s["prefill_chunks"]
        row["prefill_tokens"] = s["prefill_tokens"]
        row["shared_tokens"] = s["shared_tokens"]
        row["cow_clones"] = s["cow_clones"]
        row["paged_kernel"] = s["paged_kernel"]
    if "oversubscribe" in s:
        # pressure rows: completion rate at saturation (all arrivals at
        # t=0) IS the max sustained arrival rate — offered load beyond
        # it only grows the queue
        row["max_sustained_rate_req_s"] = (s["n_requests"]
                                           / s["makespan_s"])
        row["oversubscribe"] = s["oversubscribe"]
        row["pressure_policy"] = s["pressure_policy"]
        row["n_preemptions"] = s["n_preemptions"]
        row["oom_deferrals"] = s["oom_deferrals"]
        row["n_completed"] = s["n_completed"]
        row["n_rejected"] = s["n_rejected"]
        row["n_expired"] = s["n_expired"]
        row["swap_outs"] = s["swap_outs"]
        row["swap_ins"] = s["swap_ins"]
    return row


def make_shared_prefix_stream(key, n: int, prefix_len: int,
                              suffix_len: int, vocab: int) -> List:
    """`n` prompts sharing one `prefix_len`-token prefix (a system
    prompt / few-shot header) with distinct `suffix_len`-token tails."""
    base = np.asarray(make_lm_stream(key, n + 1, prefix_len + suffix_len,
                                     vocab))
    prefix = base[0, :prefix_len]
    return [np.concatenate([prefix, base[i + 1, prefix_len:]]
                           ).astype(np.int32) for i in range(n)]


def run(n_requests: int = 32, prompt_len: int = 16, max_new: int = 24,
        slots: int = 8, target_deferral: float = 0.4, rate: float = 0.0,
        seed: int = 0, margin: float = 0.02, min_tokens: int = 4,
        backend: str = "slot", block_size: int = 8,
        n_blocks: Optional[int] = None, prefill_chunk: int = 8,
        ragged_min: int = 0, ragged_max: int = 0,
        large_max_wait: float = 0.02,
        paged_kernel: Optional[bool] = None,
        batch_prefill: bool = True,
        shared_prefix_len: int = 0,
        shared_head_start: float = 1.0,
        socket_rate: float = 100.0,
        socket_ml_latency: float = 0.05,
        obs_cfg: Optional[ObsConfig] = None) -> Dict:
    key = jax.random.PRNGKey(seed)
    # same proxy pair as the serving driver, so bench numbers stay
    # comparable to `repro.launch.serve`
    small, large, s_cfg = build_runners("internlm2-1.8b", seed)

    ragged = ragged_min > 0
    if ragged:
        ragged_max = max(ragged_max, ragged_min)
        live = make_ragged_lm_stream(jax.random.fold_in(key, 2),
                                     n_requests, ragged_min, ragged_max,
                                     s_cfg.vocab_size)
        cal_len = (ragged_min + ragged_max) // 2
        mean_len = float(np.mean([p.shape[0] for p in live]))
        max_len = max(p.shape[0] for p in live) + max_new
    else:
        live = make_lm_stream(jax.random.fold_in(key, 2),
                              n_requests, prompt_len, s_cfg.vocab_size)
        cal_len = prompt_len
        mean_len = float(prompt_len)
        max_len = prompt_len + max_new
    cal = (make_lm_stream(jax.random.fold_in(key, 3), n_requests, cal_len,
                          s_cfg.vocab_size) if ragged else live)
    arrivals = (poisson_arrivals(n_requests, rate, seed) if rate > 0
                else None)

    static = CascadeEngine(small, large)
    # calibrate on a fixed-shape batch (the LIVE set when uniform): this
    # is a scheduling benchmark, so the request mix is pinned to the
    # target instead of floating on quantile-estimation noise.
    tau = static.calibrate(cal, cal_len, max_new, target_deferral)
    print(f"# tau={tau:.4f} (target deferral {target_deferral}), "
          f"{n_requests} requests, "
          f"prompt_len={f'{ragged_min}..{ragged_max}' if ragged else prompt_len}, "
          f"max_new={max_new}, slots={slots}, rate={rate or 'batch'}")

    def fresh():
        return make_requests(live, max_new, arrivals)

    def best_of(fn, reps: int = 2):
        """Warm-up pass (compiles every jit shape — in-process runs are
        deterministic), then `reps` timed passes; keep the fastest (wall
        clock on a shared box is noisy)."""
        fn()
        return max((fn() for _ in range(reps)),
                   key=lambda r: r["throughput_tok_s"])

    rows = []
    if not ragged:
        rows.append(best_of(lambda: run_static(static, fresh(), prompt_len,
                                               max_new, slots)))

    # -- continuous over the slot pool -------------------------------------
    cont = ContinuousCascadeEngine(small, large, n_slots=slots, tau=tau,
                                   early_exit=False, large_batch=slots,
                                   steps_per_sync=4)
    rows.append(best_of(lambda: run_continuous(cont, fresh(), max_new,
                                               "continuous")))

    # -- observability overhead row ----------------------------------------
    if obs_cfg is not None:
        # same engine/config as `continuous`, run with the observability
        # layer on: the tokens/s delta vs the row above IS the
        # instrumentation overhead (each rep re-exports the trace /
        # metrics dump, so the artifact cost is measured too)
        cont_o = ContinuousCascadeEngine(small, large, n_slots=slots,
                                         tau=tau, early_exit=False,
                                         large_batch=slots,
                                         steps_per_sync=4)
        rows.append(best_of(lambda: run_continuous(
            cont_o, fresh(), max_new, "continuous+obs", obs=obs_cfg)))

    # margin > 0 keeps eviction conservative: transient confidence dips
    # shouldn't buy an M_L regeneration that final-mean deferral wouldn't
    cont_x = ContinuousCascadeEngine(small, large, n_slots=slots, tau=tau,
                                     min_tokens=min_tokens, margin=margin,
                                     early_exit=True, large_batch=slots,
                                     steps_per_sync=4)
    rows.append(best_of(lambda: run_continuous(cont_x, fresh(), max_new,
                                               "continuous+exit")))

    # -- threaded M_L backend: deferrals regenerate off the decode loop ----
    cont_t = ContinuousCascadeEngine(small, large, n_slots=slots, tau=tau,
                                     min_tokens=min_tokens, margin=margin,
                                     early_exit=True, large_batch=slots,
                                     large_backend="thread",
                                     large_max_wait=large_max_wait,
                                     steps_per_sync=4)
    rows.append(best_of(lambda: run_continuous(cont_t, fresh(), max_new,
                                               "continuous+thread")))

    # -- 3-tier ladder: small -> mid -> large, per-edge calibrated taus ----
    # deferred traffic from edge 0 becomes arrival traffic for edge 1;
    # compute cost uses the per-tier reach fractions
    ladder = build_ladder("internlm2-1.8b", seed, 3)
    spec3 = CascadeSpec(
        tiers=[CascadeTier(r.cfg.name, runner=r, cost=c)
               for r, c in zip(ladder, (0.2, 0.45, 1.0))],
        edges=[DeferralEdge(margin=margin, min_tokens=min_tokens),
               DeferralEdge()])
    calibrate_edges(spec3, cal, max_new=max_new, prompt_len=cal_len,
                    deferral_ratio=target_deferral)
    eng3 = ContinuousCascadeEngine(spec3, EngineConfig(
        n_slots=slots, early_exit=True, steps_per_sync=4,
        ml=MLBackendConfig(large_batch=slots)))
    rows.append(best_of(lambda: run_continuous(eng3, fresh(), max_new,
                                               "continuous+3tier")))

    # -- online tau recalibration correcting a stale threshold -------------
    # the edge starts at the 0.8-quantile tau (deliberately
    # mis-calibrated: the drifted-traffic stand-in) while the controller
    # targets `target_deferral` — the recorded tau trace is the drift
    # artifact the bench record carries
    spec_r = CascadeSpec.two_tier(small, large, margin=margin,
                                  min_tokens=min_tokens)
    calibrate_edges(spec_r, cal, max_new=max_new, prompt_len=cal_len,
                    deferral_ratio=0.8)
    eng_r = ContinuousCascadeEngine(spec_r, EngineConfig(
        n_slots=slots, early_exit=True, steps_per_sync=4,
        ml=MLBackendConfig(large_batch=slots),
        recalibration=RecalibConfig(warmup=8, ewma_alpha=0.05,
                                    deadband=0.05, rearm=0.01),
        recalib_target=target_deferral))
    rows.append(best_of(lambda: run_continuous(eng_r, fresh(), max_new,
                                               "continuous+recal")))

    # -- distributed M_L tier: socket RPC, 1 replica vs 2-replica pool -----
    # deferrals cross a real localhost socket under Poisson arrivals
    # (socket_rate req/s — the SAME arrival trace for both rows, so the
    # 1-vs-2-replica deferral wait p95 comparison isolates replica
    # count). Unlike the in-process rows, M_L batches are cut at
    # slots//2 so the run produces several batches close together: with
    # one replica consecutive batches queue behind its injected service
    # time, with two they overlap — the thing replica count actually
    # controls. (At large_batch=slots the whole run fits in ~2 batches
    # that never coexist, and the p95 degenerates to group-fill time,
    # identical for any replica count.) The servers stay up across
    # reps; each rep's fresh SocketBackend opens a new session, which
    # resets server-side state.
    from repro.launch.serve import make_remote_factory
    from repro.serving.remote import MLServer

    sock_arrivals = poisson_arrivals(n_requests, socket_rate, seed)
    sock_batch = max(2, slots // 2)
    servers = [MLServer(large, max_new=max_new, large_batch=sock_batch,
                        max_wait=large_max_wait,
                        latency=socket_ml_latency).start()
               for _ in range(2)]
    try:
        for label, kind, addrs in (
                ("continuous+socket", "socket", [servers[0].address]),
                ("continuous+pool2", "pool",
                 [s.address for s in servers])):
            eng = ContinuousCascadeEngine(
                small, large, n_slots=slots, tau=tau,
                min_tokens=min_tokens, margin=margin, early_exit=True,
                large_batch=sock_batch,
                large_backend=make_remote_factory(
                    kind, addrs, connect_timeout=2.0,
                    request_timeout=30.0, retries=3,
                    health_interval=0.5),
                large_max_wait=large_max_wait, steps_per_sync=4)
            rows.append(best_of(lambda e=eng, l=label: run_continuous(
                e, make_requests(live, max_new, sock_arrivals),
                max_new, l)))
    finally:
        for srv in servers:
            srv.stop()

    # -- continuous over the block-paged pool ------------------------------
    if backend == "paged":
        if n_blocks is None:
            # budget sized for the MEAN request, not the worst case: this
            # is the regime a dense slot pool cannot fit
            per_req = math.ceil((mean_len + max_new) / block_size)
            biggest = math.ceil(max_len / block_size)
            n_blocks = max(slots * per_req, biggest)
        for label, exit_ in (("paged", False), ("paged+exit", True)):
            eng = ContinuousCascadeEngine(
                small, large, n_slots=slots, tau=tau,
                min_tokens=min_tokens, margin=margin, early_exit=exit_,
                large_batch=slots, steps_per_sync=4, backend="paged",
                block_size=block_size, n_blocks=n_blocks,
                prefill_chunk=prefill_chunk or None,
                paged_kernel=paged_kernel, batch_prefill=batch_prefill)
            rows.append(best_of(lambda e=eng, l=label: run_continuous(
                e, fresh(), max_new, l)))

    # -- prefix sharing: shared-system-prompt workload ---------------------
    if backend == "paged" and shared_prefix_len > 0:
        # 75%-shared prompts: prefix L + per-request L/3 suffix. The
        # first request arrives alone (head start) so its prompt blocks
        # are registered — and, after it retires, CACHED — before the
        # rest arrive together and map them by refcount instead of
        # prefilling them again. tau = -inf: these rows measure the
        # paged cache, not the cascade.
        L = shared_prefix_len
        suffix = max(L // 3, block_size)
        sp_prompts = make_shared_prefix_stream(
            jax.random.fold_in(key, 4), n_requests, L, suffix,
            s_cfg.vocab_size)
        sp_arrivals = np.concatenate(
            [[0.0], np.full(n_requests - 1, shared_head_start)])
        per_req = math.ceil((L + suffix + max_new - 1) / block_size)
        sp_blocks = (slots + 1) * per_req     # noshare worst case fits
        for label, share in (("paged+share", True),
                             ("paged+noshare", False)):
            eng = ContinuousCascadeEngine(
                small, large, n_slots=slots, tau=-1e9, early_exit=False,
                large_batch=slots, steps_per_sync=4, backend="paged",
                block_size=block_size, n_blocks=sp_blocks,
                prefill_chunk=prefill_chunk or None,
                paged_kernel=paged_kernel, batch_prefill=batch_prefill,
                prefix_sharing=share)
            rows.append(best_of(lambda e=eng, l=label: run_continuous(
                e, make_requests(sp_prompts, max_new, sp_arrivals),
                max_new, l)))

    # -- pressure rows: oversubscription vs reservation-only ----------------
    # The workload where reservation admission is genuinely pessimistic:
    # prompts sharing a long system prefix. reserve() charges every
    # request its full worst-case footprint, but once the first request
    # registers the prefix the physical cost of each later request is
    # only its private suffix + generation tail — so a budget sized
    # near the ACTUAL peak (shared + slots x private, one block short)
    # leaves reservation-only admission serialized at ~1 slot while the
    # oversubscribed runs fill all slots and absorb the occasional
    # tail-block collision by policy. Worst-case reservation demand of a
    # full slot set is >= 1.5x the budget (the regression gate checks
    # this). All three runs share the same tight budget and the same
    # head-start arrival trace. tau = -inf: these rows measure memory
    # pressure handling, not the cascade.
    resv_rate = None
    if backend == "paged":
        pr_prefix, pr_suffix = 12 * block_size, 2 * block_size
        pr_prompts = make_shared_prefix_stream(
            jax.random.fold_in(key, 5), n_requests, pr_prefix, pr_suffix,
            s_cfg.vocab_size)
        pr_arrivals = np.concatenate([[0.0], np.full(n_requests - 1, 0.3)])
        per_req = math.ceil((pr_prefix + pr_suffix + max_new - 1)
                            / block_size)
        shared_blocks = pr_prefix // block_size
        tight = shared_blocks + slots * (per_req - shared_blocks) - 1
        demand = slots * per_req
        # smallest virtual budget (1 decimal) that admits a full slot set
        over = math.ceil(10 * demand / tight) / 10

        def pressured(pressure_cfg, label):
            eng = ContinuousCascadeEngine(
                CascadeSpec.two_tier(small, large, tau=-1e9),
                EngineConfig(
                    n_slots=slots, early_exit=False, steps_per_sync=4,
                    backend="paged",
                    ml=MLBackendConfig(large_batch=slots),
                    paged=PagedConfig(
                        block_size=block_size, n_blocks=tight,
                        prefill_chunk=prefill_chunk or None,
                        paged_kernel=paged_kernel,
                        batch_prefill=batch_prefill,
                        pressure=pressure_cfg)))
            return best_of(lambda: run_continuous(
                eng, make_requests(pr_prompts, max_new, pr_arrivals),
                max_new, label))

        assert demand >= 1.5 * tight, (demand, tight)
        resv_row = pressured(None, "paged+resv")   # reference, not a row
        resv_rate = n_requests / resv_row["makespan_s"]
        for cfg, label in (
                (PressureConfig(oversubscribe=over, policy="preempt",
                                max_preemptions=4, swap_blocks=tight),
                 "paged+oversub"),
                (PressureConfig(oversubscribe=over, policy="shed"),
                 "paged+shed")):
            row = pressured(cfg, label)
            row["resv_rate_req_s"] = resv_rate
            rows.append(row)

    print("engine,tok_s,p50_ms,p95_ms,p99_ms,deferral,wait_ms,"
          "wait_p95_ms,ms_steps,saved_steps,cache_mb")
    for r in rows:
        print(f"{r['engine']},{r['throughput_tok_s']:.1f},"
              f"{r['latency_p50_s'] * 1e3:.0f},"
              f"{r['latency_p95_s'] * 1e3:.0f},"
              f"{r['latency_p99_s'] * 1e3:.0f},"
              f"{r['deferral_ratio']:.2f},"
              f"{r['deferral_wait_p50_ms']:.0f},"
              f"{r['deferral_wait_p95_ms']:.0f},{r['ms_steps']},"
              f"{r['saved_steps']},{r['cache_mb']:.2f}")
    base = rows[0]["throughput_tok_s"]
    best = max(rows[1:], key=lambda r: r["throughput_tok_s"]) \
        if len(rows) > 1 else rows[0]
    print(f"# best continuous ({best['engine']}) vs {rows[0]['engine']}: "
          f"{best['throughput_tok_s'] / base:.2f}x, "
          f"early-exit M_S step savings: {best['saved_steps']}")
    sock_row = next(r for r in rows if r["engine"] == "continuous+socket")
    pool_row = next(r for r in rows if r["engine"] == "continuous+pool2")
    print(f"# distributed M_L (Poisson {socket_rate:g} req/s, "
          f"{socket_ml_latency * 1e3:.0f} ms injected per-batch replica "
          f"service time): deferral wait p95 "
          f"{sock_row['deferral_wait_p95_ms']:.0f} ms on 1 "
          f"replica vs {pool_row['deferral_wait_p95_ms']:.0f} ms on a "
          f"2-replica pool "
          f"({sock_row['throughput_tok_s']:.1f} vs "
          f"{pool_row['throughput_tok_s']:.1f} tok/s)")
    t3 = next(r for r in rows if r["engine"] == "continuous+3tier")
    print(f"# 3-tier ladder: tier_served={t3['tier_served']}, per-edge "
          f"deferrals {t3['edge_deferrals']}, taus "
          f"{[round(t, 3) for t in t3['edge_tau']]}")
    rc = next(r for r in rows
              if r["engine"] == "continuous+recal")["recalibration"]
    print(f"# recalibration: tau {rc['tau_trace'][0][0][1]:.3f} -> "
          f"{rc['tau_final'][0]:.3f} in {rc['tau_updates'][0]} updates "
          f"(ewma deferral {rc['ewma_ratio'][0]:.3f}, target "
          f"{target_deferral})")
    obs_overhead = None
    if obs_cfg is not None:
        plain = next(r for r in rows if r["engine"] == "continuous")
        obs_row = next(r for r in rows if r["engine"] == "continuous+obs")
        obs_overhead = 1.0 - (obs_row["throughput_tok_s"]
                              / plain["throughput_tok_s"])
        print(f"# observability overhead: "
              f"{obs_row['throughput_tok_s']:.1f} tok/s with obs on vs "
              f"{plain['throughput_tok_s']:.1f} off "
              f"({obs_overhead:+.1%} slower)")
    if backend == "paged":
        slot_row = next(r for r in rows if r["engine"] == "continuous")
        paged_row = next(r for r in rows if r["engine"].startswith("paged"))
        dense_rows = int(paged_row["n_blocks"] * block_size // max_len)
        print(f"# cache footprint: slot pool {slot_row['cache_mb']:.2f} MiB "
              f"({slots} x {max_len}-token rows) vs paged "
              f"{paged_row['cache_mb']:.2f} MiB "
              f"({paged_row['n_blocks']} x {block_size}-token blocks, peak "
              f"{paged_row['peak_blocks']} mapped); a dense pool in the "
              f"paged budget would hold only {dense_rows} worst-case rows")
        print(f"# paged prefill: {paged_row['prefill_chunks']} chunks in "
              f"{paged_row['prefill_dispatches']} dispatches "
              f"({'batched' if batch_prefill else 'serial'}; "
              f"kernel={'pallas' if paged_row.get('paged_kernel') else 'xla'})")
    if resv_rate is not None:
        ov = next(r for r in rows if r["engine"] == "paged+oversub")
        sd = next(r for r in rows if r["engine"] == "paged+shed")
        print(f"# pressure ({ov['n_blocks']}-block tight budget, "
              f"reservation demand {demand} blocks = "
              f"{demand / ov['n_blocks']:.1f}x, "
              f"{ov['oversubscribe']:g}x oversubscribed): max sustained "
              f"rate {resv_rate:.2f} req/s reservation-only -> "
              f"{ov['max_sustained_rate_req_s']:.2f} req/s preempt "
              f"({ov['n_preemptions']} preemptions, "
              f"{ov['oom_deferrals']} OOM deferrals, "
              f"{ov['n_completed']}/{n_requests} completed, "
              f"{ov['swap_outs']}/{ov['swap_ins']} swap out/in) vs "
              f"{sd['max_sustained_rate_req_s']:.2f} req/s shed "
              f"({sd['n_rejected']} rejected)")
    if backend == "paged" and shared_prefix_len > 0:
        sh = next(r for r in rows if r["engine"] == "paged+share")
        ns = next(r for r in rows if r["engine"] == "paged+noshare")
        suffix = max(shared_prefix_len // 3, block_size)
        blk_x = ns["peak_blocks"] / max(sh["peak_blocks"], 1)
        tok_x = ns["prefill_tokens"] / max(sh["prefill_tokens"], 1)
        print(f"# prefix sharing ({shared_prefix_len}-token prefix + "
              f"{suffix}-token suffix, "
              f"{shared_prefix_len / (shared_prefix_len + suffix):.0%} "
              f"shared): peak mapped blocks {ns['peak_blocks']} -> "
              f"{sh['peak_blocks']} ({blk_x:.1f}x), prefilled tokens "
              f"{ns['prefill_tokens']} -> {sh['prefill_tokens']} "
              f"({tok_x:.1f}x); {sh['shared_tokens']} prompt tokens "
              f"served from shared blocks, {sh['cow_clones']} CoW clones")
    payload = {"tau": tau, "config": {
        "n_requests": n_requests, "prompt_len": prompt_len,
        "max_new": max_new, "slots": slots, "rate": rate,
        "target_deferral": target_deferral, "backend": backend,
        "block_size": block_size, "n_blocks": n_blocks,
        "ragged_min": ragged_min, "ragged_max": ragged_max,
        "large_max_wait": large_max_wait, "paged_kernel": paged_kernel,
        "batch_prefill": batch_prefill,
        "shared_prefix_len": shared_prefix_len,
        "socket_rate": socket_rate,
        "socket_ml_latency": socket_ml_latency}, "rows": rows,
        "obs_overhead": obs_overhead}
    save_result("serving", payload)
    for r in rows:
        emit_csv_row(f"serving/{r['engine']}",
                     r["makespan_s"] * 1e6,
                     f"{r['throughput_tok_s']:.1f} tok/s")
    return payload


def bench_record(payload: Dict) -> Dict:
    """The machine-readable slice of a bench run that the CI regression
    gate compares: per-engine tokens/s, p95 latency, deferral ratio and
    wait. Written to --bench-out / benchmarks/baselines/*.json."""
    return {
        "config": payload["config"],
        "rows": [{
            "engine": r["engine"],
            "tokens_per_s": round(r["throughput_tok_s"], 2),
            "p95_latency_ms": round(r["latency_p95_s"] * 1e3, 2),
            "queueing_p95_s":
                (round(r["queueing_p95_s"], 4)
                 if np.isfinite(r.get("queueing_p95_s", float("nan")))
                 else None),
            "deferral_ratio": round(r["deferral_ratio"], 4),
            "deferral_wait_p50_ms":
                (round(r["deferral_wait_p50_ms"], 2)
                 if np.isfinite(r["deferral_wait_p50_ms"]) else None),
            "deferral_wait_p95_ms":
                (round(r["deferral_wait_p95_ms"], 2)
                 if np.isfinite(r["deferral_wait_p95_ms"]) else None),
            "phase_breakdown_s": {
                k[len("phase_"):-len("_s")]: round(v, 4)
                for k, v in r.items()
                if k.startswith("phase_") and k.endswith("_s")},
            **({"tier_served": r["tier_served"],
                "edge_deferrals": r["edge_deferrals"],
                "edge_tau": [round(t, 4) for t in r["edge_tau"]]}
               if "tier_served" in r else {}),
            # pressure rows: capacity + eviction accounting the gate
            # watches alongside tokens/s
            **({"max_sustained_rate_req_s":
                    round(r["max_sustained_rate_req_s"], 3),
                "resv_rate_req_s": round(r["resv_rate_req_s"], 3),
                "pressure_policy": r["pressure_policy"],
                "n_preemptions": r["n_preemptions"],
                "oom_deferrals": r["oom_deferrals"],
                "n_completed": r["n_completed"],
                "n_rejected": r["n_rejected"]}
               if "max_sustained_rate_req_s" in r else {}),
            # tau drift is a first-class bench artifact: initial tau,
            # where the online controller left it, and the trace
            **({"tau_drift": {
                "tau0": r["recalibration"]["tau_trace"][0][0][1],
                "tau_final": [round(t, 4)
                              for t in r["recalibration"]["tau_final"]],
                "updates": r["recalibration"]["tau_updates"],
                "trace": r["recalibration"]["tau_trace"]}}
               if "recalibration" in r else {}),
        } for r in payload["rows"]],
    }


def check_baseline(record: Dict, baseline_path: str,
                   max_drop: float = 0.25) -> List[str]:
    """Compare a bench record against the committed baseline: any
    engine row whose tokens/s fell more than `max_drop` below baseline
    is a regression. Returns failure messages (empty = pass). Rows
    added since the baseline was written are reported but don't fail;
    rows *missing* from the current run do."""
    with open(baseline_path) as f:
        base = json.load(f)
    base_rows = {r["engine"]: r for r in base["rows"]}
    cur_rows = {r["engine"]: r for r in record["rows"]}
    failures = []
    for engine, b in base_rows.items():
        cur = cur_rows.get(engine)
        if cur is None:
            failures.append(f"{engine}: present in baseline but missing "
                            f"from this run")
            continue
        floor = b["tokens_per_s"] * (1.0 - max_drop)
        status = "ok" if cur["tokens_per_s"] >= floor else "REGRESSION"
        print(f"# baseline {engine}: {cur['tokens_per_s']:.1f} tok/s vs "
              f"{b['tokens_per_s']:.1f} baseline "
              f"(floor {floor:.1f}) -> {status}")
        if status != "ok":
            failures.append(
                f"{engine}: {cur['tokens_per_s']:.1f} tok/s is "
                f">{max_drop:.0%} below baseline "
                f"{b['tokens_per_s']:.1f} (floor {floor:.1f})")
    for engine in cur_rows.keys() - base_rows.keys():
        print(f"# baseline {engine}: new row (not in baseline; run "
              f"--update-baseline to start gating it)")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--target-deferral", type=float, default=0.4)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrivals/s (0 = all requests at t=0)")
    ap.add_argument("--margin", type=float, default=0.02)
    ap.add_argument("--min-tokens", type=int, default=4)
    ap.add_argument("--backend", choices=("slot", "paged"), default="slot",
                    help="'paged' adds block-paged rows + footprint "
                         "comparison against the slot pool")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--blocks", type=int, default=0,
                    help="paged block budget (0 = auto: sized for the "
                         "mean request)")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="paged prefill chunk tokens (0 = whole prompt)")
    ap.add_argument("--ragged-min", type=int, default=0,
                    help=">0: ragged workload, prompt lengths uniform in "
                         "[ragged-min, ragged-max]")
    ap.add_argument("--ragged-max", type=int, default=0)
    ap.add_argument("--large-max-wait", type=float, default=0.02,
                    help="threaded M_L backend: seconds a partial batch "
                         "may wait before flushing")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="route paged decode through the Pallas paged "
                         "flash-decode kernels (interpret mode on CPU — "
                         "Python-speed; for kernel-path measurement, not "
                         "CI gating)")
    ap.add_argument("--serial-prefill", action="store_true",
                    help="disable batched paged prefill (one request's "
                         "chunk per engine iteration, the old loop)")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help=">0: add paged+share / paged+noshare rows on a "
                         "shared-system-prompt workload (prefix of this "
                         "many tokens + per-request suffix of a third), "
                         "reporting peak-mapped-block and prefill-token "
                         "reductions (needs --backend paged)")
    ap.add_argument("--shared-head-start", type=float, default=1.0,
                    help="seconds the first shared-prefix request runs "
                         "alone so its prompt blocks are registered "
                         "before the rest arrive together")
    ap.add_argument("--socket-rate", type=float, default=100.0,
                    help="Poisson arrival rate (req/s) for the "
                         "continuous+socket / continuous+pool2 rows "
                         "(the 1-vs-2-replica deferral-wait comparison)")
    ap.add_argument("--socket-ml-latency", type=float, default=0.05,
                    help="injected per-batch M_L replica service time "
                         "(s) for the socket/pool rows — models the "
                         "remote accelerator so the 1-vs-2-replica "
                         "comparison measures queueing, not single-"
                         "core CPU contention")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-row", action="store_true",
                    help="add a continuous+obs row (the continuous "
                         "config with the observability layer on) and "
                         "gate its tokens/s within --obs-gate of the "
                         "plain row; implied by any obs output flag")
    ap.add_argument("--obs-gate", type=float, default=0.05,
                    help="allowed fractional tokens/s overhead of the "
                         "continuous+obs row vs continuous (exit 1 "
                         "beyond it)")
    ap.add_argument("--bench-out", default=None,
                    help="write the machine-readable bench record "
                         "(tokens/s, p95, deferral, queueing p95, phase "
                         "breakdown) to this JSON path")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to gate against: "
                         "exit 1 if any engine's tokens/s drops >25%% "
                         "below it")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline from this run instead of "
                         "gating (commit the result)")
    ap.add_argument("--max-drop", type=float, default=0.25,
                    help="allowed fractional tokens/s drop vs baseline")
    add_obs_args(ap)
    args = ap.parse_args()
    base_obs = obs_config_from_args(args)
    obs_cfg = (base_obs if (args.obs_row or base_obs.any_enabled
                            or base_obs.max_events is not None) else None)
    payload = run(args.requests, args.prompt_len, args.max_new, args.slots,
                  args.target_deferral, args.rate, args.seed, args.margin,
                  args.min_tokens, args.backend, args.block_size,
                  args.blocks or None, args.prefill_chunk,
                  args.ragged_min, args.ragged_max, args.large_max_wait,
                  args.paged_kernel or None, not args.serial_prefill,
                  args.shared_prefix_len, args.shared_head_start,
                  args.socket_rate, args.socket_ml_latency,
                  obs_cfg=obs_cfg)
    record = bench_record(payload)
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# bench record written to {args.bench_out}")
    if args.baseline and args.update_baseline:
        with open(args.baseline, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# baseline updated: {args.baseline} (commit this file)")
    elif args.baseline:
        failures = check_baseline(record, args.baseline, args.max_drop)
        if failures:
            print("# BENCHMARK REGRESSION:\n#  " + "\n#  ".join(failures))
            sys.exit(1)
        print("# baseline check passed")
    if payload.get("obs_overhead") is not None:
        oh = payload["obs_overhead"]
        if oh > args.obs_gate:
            print(f"# OBSERVABILITY OVERHEAD REGRESSION: continuous+obs "
                  f"is {oh:.1%} slower than continuous "
                  f"(allowed {args.obs_gate:.0%})")
            sys.exit(1)
        print(f"# observability overhead gate passed "
              f"({oh:+.1%} <= {args.obs_gate:.0%})")


if __name__ == "__main__":
    main()
