"""Serving benchmark: static lock-step cascade vs continuous batching with
in-flight deferral, on the same synthetic request stream.

Scenarios (same models, same calibrated tau, same prompts):
  * static            — batches of `slots` requests, each decoded for the
                        full `max_new` on M_S before the deferral decision
  * continuous        — slot pool + FIFO admission, early exit disabled
                        (pure scheduling comparison / parity path)
  * continuous+exit   — in-flight deferral: requests whose running mean
                        confidence drops below tau are evicted early,
                        freeing their slot for the next arrival

Each scenario is run once untimed (compile warm-up; in-process runs are
deterministic, so the warm-up covers every jit shape the timed run needs)
and once timed. Reported per scenario: tokens/s, latency percentiles,
deferral ratio, M_S decode steps executed and steps saved by early exit.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.data.synthetic import make_lm_stream
from repro.launch.serve import build_runners
from repro.serving import (CascadeEngine, ContinuousCascadeEngine,
                           make_requests, poisson_arrivals)

from benchmarks.common import emit_csv_row, save_result


def run_static(engine: CascadeEngine, requests: List, prompt_len: int,
               max_new: int, batch_size: int) -> Dict:
    """Lock-step serving under the arrival trace: wait until `batch_size`
    requests have arrived, serve them for the full max_new, repeat."""
    order = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    t0 = time.perf_counter()
    lat, n_deferred = [], 0
    i = 0
    steps = 0
    while i < len(order):
        batch = order[i:i + batch_size]
        while time.perf_counter() - t0 < batch[-1].arrival_time:
            time.sleep(1e-4)
        prompts = np.stack([r.prompt for r in batch])
        res = engine.serve(prompts, prompt_len, max_new)
        now = time.perf_counter() - t0
        lat.extend(now - r.arrival_time for r in batch)
        n_deferred += int(res.deferred.sum())
        steps += max_new - 1
        i += len(batch)
    makespan = time.perf_counter() - t0
    lat = np.array(lat)
    n = len(order)
    return {
        "engine": "static",
        "makespan_s": makespan,
        "throughput_tok_s": n * max_new / makespan,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "deferral_ratio": n_deferred / n,
        "ms_steps": steps,
        "saved_steps": 0,
    }


def run_continuous(engine: ContinuousCascadeEngine, requests: List,
                   prompt_len: int, max_new: int, label: str) -> Dict:
    res = engine.run(requests, prompt_len, max_new)
    s = res.stats
    return {
        "engine": label,
        "makespan_s": s["makespan_s"],
        "throughput_tok_s": s["throughput_tok_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p99_s": s["latency_p99_s"],
        "deferral_ratio": s["deferral_ratio"],
        "ms_steps": res.steps,
        "saved_steps": res.saved_steps,
    }


def run(n_requests: int = 32, prompt_len: int = 16, max_new: int = 24,
        slots: int = 8, target_deferral: float = 0.4, rate: float = 0.0,
        seed: int = 0, margin: float = 0.02, min_tokens: int = 4) -> Dict:
    key = jax.random.PRNGKey(seed)
    # same proxy pair as the serving driver, so bench numbers stay
    # comparable to `repro.launch.serve`
    small, large, s_cfg = build_runners("internlm2-1.8b", seed)

    live = make_lm_stream(jax.random.fold_in(key, 2),
                          n_requests, prompt_len, s_cfg.vocab_size)
    arrivals = (poisson_arrivals(n_requests, rate, seed) if rate > 0
                else None)

    static = CascadeEngine(small, large)
    # calibrate on the LIVE set: this is a scheduling benchmark, so the
    # request mix (realized deferral ratio) is pinned to the target
    # instead of floating on quantile-estimation noise.
    tau = static.calibrate(live, prompt_len, max_new, target_deferral)
    print(f"# tau={tau:.4f} (target deferral {target_deferral}), "
          f"{n_requests} requests, prompt_len={prompt_len}, "
          f"max_new={max_new}, slots={slots}, rate={rate or 'batch'}")

    def fresh():
        return make_requests(live, max_new, arrivals)

    def best_of(fn, reps: int = 2):
        """Warm-up pass (compiles every jit shape — in-process runs are
        deterministic), then `reps` timed passes; keep the fastest (wall
        clock on a shared box is noisy)."""
        fn()
        return max((fn() for _ in range(reps)),
                   key=lambda r: r["throughput_tok_s"])

    rows = [best_of(lambda: run_static(static, fresh(), prompt_len,
                                       max_new, slots))]

    # -- continuous, early exit off ---------------------------------------
    cont = ContinuousCascadeEngine(small, large, n_slots=slots, tau=tau,
                                   early_exit=False, large_batch=slots,
                                   steps_per_sync=4)
    rows.append(best_of(lambda: run_continuous(cont, fresh(), prompt_len,
                                               max_new, "continuous")))

    # -- continuous, in-flight deferral -----------------------------------
    # margin > 0 keeps eviction conservative: transient confidence dips
    # shouldn't buy an M_L regeneration that final-mean deferral wouldn't
    cont_x = ContinuousCascadeEngine(small, large, n_slots=slots, tau=tau,
                                     min_tokens=min_tokens, margin=margin,
                                     early_exit=True, large_batch=slots,
                                     steps_per_sync=4)
    rows.append(best_of(lambda: run_continuous(cont_x, fresh(), prompt_len,
                                               max_new, "continuous+exit")))

    print("engine,tok_s,p50_ms,p99_ms,deferral,ms_steps,saved_steps")
    for r in rows:
        print(f"{r['engine']},{r['throughput_tok_s']:.1f},"
              f"{r['latency_p50_s'] * 1e3:.0f},"
              f"{r['latency_p99_s'] * 1e3:.0f},"
              f"{r['deferral_ratio']:.2f},{r['ms_steps']},"
              f"{r['saved_steps']}")
    base = rows[0]["throughput_tok_s"]
    best = rows[-1]
    print(f"# continuous+exit speedup over static: "
          f"{best['throughput_tok_s'] / base:.2f}x, "
          f"early-exit M_S step savings: {best['saved_steps']}")
    payload = {"tau": tau, "config": {
        "n_requests": n_requests, "prompt_len": prompt_len,
        "max_new": max_new, "slots": slots, "rate": rate,
        "target_deferral": target_deferral}, "rows": rows}
    save_result("serving", payload)
    for r in rows:
        emit_csv_row(f"serving/{r['engine']}",
                     r["makespan_s"] * 1e6,
                     f"{r['throughput_tok_s']:.1f} tok/s")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--target-deferral", type=float, default=0.4)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrivals/s (0 = all requests at t=0)")
    ap.add_argument("--margin", type=float, default=0.02)
    ap.add_argument("--min-tokens", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.requests, args.prompt_len, args.max_new, args.slots,
        args.target_deferral, args.rate, args.seed, args.margin,
        args.min_tokens)


if __name__ == "__main__":
    main()
