"""Render the §Dry-run/§Roofline markdown tables from dryrun jsonl files.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        benchmarks/results/dryrun.jsonl
"""
import json
import sys
from collections import OrderedDict


def load(path):
    rows = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r.get("remat", "none"))] = r
    return list(rows.values())        # last write wins per combo


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def gb(x):
    return f"{x/2**30:.1f}"


def main(paths):
    for path in paths:
        rows = load(path)
        print(f"\n### {path} ({len(rows)} combos)\n")
        print("| arch | shape | compute | memory | collective | dominant |"
              " useful-FLOPs | args GiB/dev | temp GiB/dev | fits 16G |"
              " compile s |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"{r['dominant']} | {r['useful_flops_ratio']:.3g} | "
                  f"{gb(r['arg_bytes'])} | {gb(r['temp_bytes'])} | "
                  f"{'Y' if r.get('fits_hbm') else 'N'} | "
                  f"{r.get('t_compile_s', 0):.0f} |")
        # hillclimb candidate picks
        worst_ratio = min((r for r in rows if r["useful_flops_ratio"] > 0),
                          key=lambda r: r["useful_flops_ratio"], default=None)
        coll = max(rows, key=lambda r: (r["collective_s"] /
                                        max(r["compute_s"] + r["memory_s"],
                                            1e-12)))
        if worst_ratio:
            print(f"\nworst useful-FLOPs ratio: {worst_ratio['arch']} x "
                  f"{worst_ratio['shape']} ({worst_ratio['useful_flops_ratio']:.3g})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} "
              f"(coll/(comp+mem) = "
              f"{coll['collective_s']/max(coll['compute_s']+coll['memory_s'],1e-12):.3g})")


if __name__ == "__main__":
    main(sys.argv[1:] or ["benchmarks/results/dryrun.jsonl"])
