"""Paper Fig. 6: decoder-only LM cascade on closed-form QA, alpha sweep +
the App. B.2 prompting baselines ("Reduce Confidence", "Answer N").

CPU-scale instantiation: synthetic QA (copy / modular add / modular mul,
mirroring ARC-e vs ARC-c difficulty), 1-layer M_S vs 4-layer M_L decoders,
g_NENT deferral on the answer token (eq. 8).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.baselines import PromptingBaseline
from repro.core.deferral import sequence_negative_entropy
from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_qa
from repro.models import transformer as tfm
from repro.sharding import ParallelContext
from repro.training import optim
from repro.training.loop import make_train_step, train

from benchmarks.common import emit_csv_row, save_result

ALPHAS = (0.05, 0.2, 0.5, 0.8)
CTX = ParallelContext()


def _mk_cfg(name, layers, d):
    return ModelConfig(name=name, family="dense", n_layers=layers, d_model=d,
                       n_heads=4, n_kv_heads=4, head_dim=d // 4, d_ff=d * 4,
                       vocab_size=32, tie_embeddings=True)


def _train_lm(cfg, data, seed, steps, loss_kind="ce", gk=None, init=None,
              lr=3e-3):
    params = init if init is not None else tfm.init_params(
        cfg, jax.random.PRNGKey(seed))
    apply_fn = lambda p, b: tfm.forward(p, cfg, b["inputs"], CTX)
    it = BatchIterator({"inputs": data.inputs, "targets": data.targets,
                        "loss_mask": data.loss_mask}, 256,
                       key=jax.random.PRNGKey(seed))
    step = make_train_step(apply_fn, optim.AdamWConfig(lr=lr,
                                                       total_steps=steps),
                           loss_kind=loss_kind, gk_cfg=gk)
    return train(params, step, it.forever(), steps, log_every=10**9).params


def _answer_metrics(cfg, params, data, confidence=None):
    """Answer-token correctness + g_NENT confidence per example."""
    logits = tfm.forward(params, cfg, jnp.asarray(data.inputs), CTX)
    ans_pos = data.answer_pos - 1          # position predicting the answer
    ans_logits = logits[:, ans_pos, :]
    preds = np.asarray(jnp.argmax(ans_logits, -1))
    correct = (preds == data.targets[:, ans_pos]).astype(np.float64)
    if confidence is None:
        conf = np.asarray(sequence_negative_entropy(
            logits, jnp.asarray(data.loss_mask)))
    else:
        conf = confidence(ans_logits)
    return conf, correct


def run(n_train=8000, n_test=3000, steps=400, gk_steps=250, seed=0):
    key = jax.random.PRNGKey(seed)
    tr = make_qa(key, n_train)
    te = make_qa(jax.random.fold_in(key, 1), n_test)
    s_cfg = _mk_cfg("lm-small", 1, 64)
    l_cfg = _mk_cfg("lm-large", 4, 192)

    t0 = time.perf_counter()
    small = _train_lm(s_cfg, tr, 1, steps)
    large = _train_lm(l_cfg, tr, 2, steps + 200)
    _, lcorr = _answer_metrics(l_cfg, large, te)

    rows = {}
    conf, corr = _answer_metrics(s_cfg, small, te)
    rows["baseline"] = summarize_deferral(conf, corr, lcorr)

    # prompting baselines (App. B.2) — black-box prompt modifications on the
    # UNtuned model; the paper reports they do not help.
    for kind in ("reduce_confidence", "answer_n"):
        pb = PromptingBaseline(kind)
        inputs = np.asarray(pb.modify_inputs(jnp.asarray(te.inputs)))
        logits = tfm.forward(small, s_cfg, jnp.asarray(inputs), CTX)
        ans_logits = logits[:, te.answer_pos - 1, :]
        conf_pb = np.asarray(pb.confidence_from_logits(ans_logits))
        preds = np.asarray(jnp.argmax(ans_logits, -1))
        corr_pb = (preds == te.targets[:, te.answer_pos - 1]).astype(float)
        rows[f"prompt:{kind}"] = summarize_deferral(conf_pb, corr_pb, lcorr)

    for a in ALPHAS:
        tuned = _train_lm(s_cfg, tr, 3, gk_steps, loss_kind="gatekeeper",
                          gk=GatekeeperConfig(alpha=a), init=small, lr=1e-3)
        conf, corr = _answer_metrics(s_cfg, tuned, te)
        rows[f"alpha={a}"] = summarize_deferral(conf, corr, lcorr)
    elapsed = time.perf_counter() - t0

    payload = {k: {m: v[m] for m in ("s_d", "s_o", "auroc", "acc_small",
                                     "acc_large")}
               for k, v in rows.items()}
    save_result("fig6_lm", payload)
    for k, v in payload.items():
        emit_csv_row(f"fig6/{k}", elapsed / len(rows) * 1e6,
                     f"s_d={v['s_d']:.3f};s_o={v['s_o']:.3f};"
                     f"auroc={v['auroc']:.3f};acc={v['acc_small']:.3f}")
    return payload


if __name__ == "__main__":
    run()
