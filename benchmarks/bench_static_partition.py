"""Related-work comparison (paper §2, Rawat et al. 2021): static easy/hard
pre-partition vs Gatekeeper's dynamic partition, matched budgets."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import compute_static_partition
from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_classification
from repro.models.classifier import (MLPClassifierConfig, classifier_forward,
                                     init_classifier)
from repro.training import optim
from repro.training.loop import evaluate_classifier, make_train_step, train

from benchmarks.common import emit_csv_row, save_result


def run(n_train=3000, n_test=3000, steps=2500, ft_steps=1500, seed=0):
    key = jax.random.PRNGKey(seed)
    tr = make_classification(key, n_train, n_classes=8, hard_frac=0.45)
    tr_l = make_classification(jax.random.fold_in(key, 5), 25000, 8,
                               hard_frac=0.45)
    cal = make_classification(jax.random.fold_in(key, 7), 4000, 8,
                              hard_frac=0.45)
    te = make_classification(jax.random.fold_in(key, 1), n_test, 8,
                             hard_frac=0.45)
    s_cfg = MLPClassifierConfig(d_in=tr.x.shape[1], n_classes=8,
                                hidden=(64, 64))
    l_cfg = MLPClassifierConfig(d_in=tr.x.shape[1], n_classes=8,
                                hidden=(256, 256))

    def fit(cfg, seed_, steps_, loss_kind="ce", gk=None, init=None,
            extra=None, lr=3e-3, data=None):
        data = tr if data is None else data
        params = init if init is not None else init_classifier(
            cfg, jax.random.PRNGKey(seed_))
        arrays = {"inputs": data.x, "targets": data.y}
        if extra:
            arrays.update(extra)
        it = BatchIterator(arrays, 256, key=jax.random.PRNGKey(seed_))
        step = make_train_step(
            lambda p, b: classifier_forward(p, cfg, b["inputs"]),
            optim.AdamWConfig(lr=lr, total_steps=steps_),
            loss_kind=loss_kind, gk_cfg=gk)
        return train(params, step, it.forever(), steps_,
                     log_every=10**9).params

    t0 = time.perf_counter()
    small = fit(s_cfg, 1, steps)
    large = fit(l_cfg, 2, 4000, data=tr_l)
    _, _, lcorr = evaluate_classifier(
        lambda p, x: classifier_forward(p, l_cfg, x), large, te.x, te.y)

    def metrics_of(params):
        _, conf, corr = evaluate_classifier(
            lambda p, x: classifier_forward(p, s_cfg, x), params, te.x, te.y)
        return summarize_deferral(conf, corr, lcorr)

    # Rawat'21: the partition is frozen ONCE from the pre-finetune model
    # (on the calibration split, same data budget as Gatekeeper's stage 2)
    ref_logits = classifier_forward(small, s_cfg, jnp.asarray(cal.x))
    easy = np.asarray(compute_static_partition(ref_logits,
                                               jnp.asarray(cal.y)))
    static = fit(s_cfg, 3, ft_steps, loss_kind="static_partition",
                 gk=GatekeeperConfig(alpha=0.05), init=small,
                 extra={"easy_mask": easy}, lr=5e-3, data=cal)
    dynamic = fit(s_cfg, 3, ft_steps, loss_kind="gatekeeper",
                  gk=GatekeeperConfig(alpha=0.05), init=small, lr=5e-3,
                  data=cal)
    elapsed = time.perf_counter() - t0

    payload = {
        "baseline": metrics_of(small),
        "static_partition(Rawat21)": metrics_of(static),
        "gatekeeper_dynamic": metrics_of(dynamic),
    }
    payload = {k: {m: v[m] for m in ("s_d", "s_o", "auroc", "acc_small")}
               for k, v in payload.items()}
    save_result("static_vs_dynamic", payload)
    for k, v in payload.items():
        emit_csv_row(f"rawat21/{k}", elapsed / 3 * 1e6,
                     f"s_d={v['s_d']:.3f};auroc={v['auroc']:.3f};"
                     f"acc={v['acc_small']:.3f}")
    return payload


if __name__ == "__main__":
    run()
