"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode (Python — timings are NOT
hardware-representative); what we measure here is the XLA *fused chunked*
Gatekeeper loss / entropy path against the naive materialize-[T,V] path,
plus derived roofline units (bytes avoided) for the TPU target.

Paged serving rows: the dense-gather XLA decode (all M table entries) vs
the active-prefix gather vs the Pallas paged flash-decode kernel at
several resident lengths — timed where meaningful, plus the modeled
HBM bytes/step each path moves on the TPU target — and batched vs
serial paged prefill-chunk dispatch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deferral import negative_entropy
from repro.core.gatekeeper import GatekeeperConfig, gatekeeper_loss
from repro.kernels import ops as kops
from repro.launch.steps import chunked_gatekeeper_loss, fused_confidence
from repro.models.attention import gather_blocks

from benchmarks.common import emit_csv_row, save_result, time_call

GK = GatekeeperConfig(alpha=0.3)


def bench_paged_decode(key, results):
    """Per-decoded-token KV traffic of the paged backends. The dense
    gather reads every table entry (M blocks/row) no matter how short the
    residents are; the active-prefix gather and the Pallas kernel read
    only ceil(resident/bs) blocks. CPU timings cover the two XLA paths;
    the interpret-mode kernel is timed once for reference but its cost
    model (bytes/step) is the TPU-relevant number."""
    B, H, KV, hd, bs, max_len = 8, 8, 2, 64, 16, 1024
    M = max_len // bs
    N = B * M + 1
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (N, bs, KV, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (N, bs, KV, hd), jnp.float32)
    perm = np.random.default_rng(0).permutation(N - 1) + 1
    tables = jnp.asarray(perm.reshape(B, M), jnp.int32)

    @jax.jit
    def gather_decode(q, kp, vp, tbl, pos):
        kk, vv = gather_blocks(kp, tbl), gather_blocks(vp, tbl)
        S = kk.shape[1]
        mask = jnp.arange(S)[None, :] <= pos[:, None]
        qg = q.reshape(B, 1, KV, H // KV, hd)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, kk) / np.sqrt(hd)
        s = jnp.where(mask[:, None, None, None, :], s, -1e30)
        return jnp.einsum("bkgts,bskh->btkgh", jax.nn.softmax(s, -1), vv)

    leaf_bytes = bs * KV * hd * 4 * 2                # k + v, fp32
    rows = {}
    for resident in (64, 256, 1024):
        pos = jnp.full((B,), resident - 1, jnp.int32)
        mb = math.ceil(resident / bs)
        t_dense = time_call(
            lambda: np.asarray(gather_decode(q, kp, vp, tables, pos)))
        t_active = time_call(
            lambda: np.asarray(gather_decode(q, kp, vp,
                                             tables[:, :mb], pos)))
        row = {
            "us_xla_dense_gather": t_dense,
            "us_xla_active_prefix": t_active,
            "hbm_bytes_step_dense": B * M * leaf_bytes,
            "hbm_bytes_step_kernel": B * mb * leaf_bytes,
        }
        if resident == 64:   # interpret-mode kernel: Python-speed, time once
            row["us_pallas_interpret"] = time_call(
                lambda: np.asarray(kops.paged_flash_decode_gqa(
                    q, kp, vp, tables[:, :mb], pos)), iters=2)
        rows[f"resident_{resident}"] = row
        emit_csv_row(f"kernel/paged_decode_r{resident}", t_active,
                     f"dense={t_dense:.0f}us;"
                     f"bytes {B * M * leaf_bytes / 1e6:.1f}->"
                     f"{B * mb * leaf_bytes / 1e6:.1f}MB/step")
    results["paged_decode"] = rows


def bench_batched_prefill(key, results):
    """Host-dispatch amortization of batched paged prefill: the same
    8 x [1, C] chunk dispatches packed as 1 x [8, C]."""
    from repro.configs import get_config, reduced
    from repro.models import transformer as tfm
    from repro.sharding import ParallelContext
    cfg = reduced(get_config("internlm2-1.8b"))
    params = tfm.init_params(cfg, key)
    ctx = ParallelContext()
    Bc, C, bs, n_blocks = 8, 16, 8, 64
    cache = tfm.init_cache(cfg, n_blocks + 1, bs, dtype=cfg.cdtype())
    M = 4
    perm = np.random.default_rng(1).permutation(n_blocks)[:Bc * M] + 1
    tables = jnp.asarray(perm.reshape(Bc, M), jnp.int32)
    toks = jax.random.randint(jax.random.fold_in(key, 3), (Bc, C), 0,
                              cfg.vocab_size)

    @jax.jit
    def chunk(params, tokens, tbl, cache):
        logits, cache = tfm.prefill(params, cfg, tokens, cache, ctx,
                                    cache_offset=0, pages=tbl,
                                    last_index=C - 1)
        return logits[:, 0, :], cache

    def serial():
        out = []
        for i in range(Bc):
            lg, _ = chunk(params, toks[i:i + 1], tables[i:i + 1], cache)
            out.append(lg)
        return np.asarray(jnp.concatenate(out))

    def batched():
        lg, _ = chunk(params, toks, tables, cache)
        return np.asarray(lg)

    t_serial = time_call(serial)
    t_batched = time_call(batched)
    results["batched_prefill"] = {
        "us_serial_8x1": t_serial, "us_batched_1x8": t_batched,
        "dispatches_serial": Bc, "dispatches_batched": 1,
        "speedup": t_serial / max(t_batched, 1e-9),
    }
    emit_csv_row("kernel/batched_prefill", t_batched,
                 f"serial={t_serial:.0f}us;"
                 f"{t_serial / max(t_batched, 1e-9):.2f}x")


def run():
    key = jax.random.PRNGKey(0)
    results = {}
    # moderate CPU-feasible proxy of the V=163840 regime
    B, S, d, V = 8, 128, 256, 16384
    x = jax.random.normal(key, (B, S, d), jnp.float32)
    table = jax.random.normal(jax.random.fold_in(key, 1), (V, d))
    tgt = jax.random.randint(key, (B, S), 0, V)

    naive = jax.jit(lambda x, t, y: gatekeeper_loss(
        jnp.einsum("bsd,vd->bsv", x, t), y, GK)[0])
    fused = jax.jit(lambda x, t, y: chunked_gatekeeper_loss(
        x, t, y, GK, n_chunks=16)[0])
    t_naive = time_call(lambda: float(naive(x, table, tgt)))
    t_fused = time_call(lambda: float(fused(x, table, tgt)))
    # bytes the fused path avoids writing+reading in HBM (fp32 logits x3)
    avoided = B * S * V * 4 * 3
    results["gatekeeper_loss"] = {
        "us_naive": t_naive, "us_fused": t_fused,
        "hbm_bytes_avoided": avoided,
        "tpu_memory_term_saved_s": avoided / 819e9,
    }
    emit_csv_row("kernel/gatekeeper_fused", t_fused,
                 f"naive={t_naive:.0f}us;avoided={avoided/1e6:.0f}MB")

    # deferral entropy at decode: [128, 16384]
    logits = jax.random.normal(key, (128, V))
    naive_e = jax.jit(lambda l: negative_entropy(l))
    xf = jax.random.normal(key, (128, d))
    fused_e = jax.jit(lambda x, t: fused_confidence(x, t, n_chunks=8)[0])
    t_naive = time_call(lambda: np.asarray(naive_e(logits)))
    t_fused = time_call(lambda: np.asarray(fused_e(xf, table)))
    results["deferral_entropy"] = {"us_naive": t_naive, "us_fused": t_fused}
    emit_csv_row("kernel/deferral_entropy", t_fused,
                 f"naive_from_logits={t_naive:.0f}us")

    # WKV recurrence: naive per-token scan vs chunk-parallel (the Pallas
    # kernel's algorithm; interpret-mode timing is not meaningful, so we
    # time the XLA chunked path it mirrors and report the state-traffic
    # the VMEM-resident kernel avoids)
    from repro.models.ssm import (linear_attention_chunked,
                                  linear_attention_scan)
    Bw, Tw, Hw, Kw = 4, 256, 4, 64
    kk = jax.random.split(jax.random.fold_in(key, 7), 6)
    qw = jax.random.normal(kk[0], (Bw, Tw, Hw, Kw)) * 0.5
    kw = jax.random.normal(kk[1], (Bw, Tw, Hw, Kw)) * 0.5
    vw = jax.random.normal(kk[2], (Bw, Tw, Hw, Kw)) * 0.5
    lw = -jax.random.uniform(kk[3], (Bw, Tw, Hw, Kw), minval=0.05, maxval=1.0)
    uw = jax.random.normal(kk[4], (Hw, Kw)) * 0.3
    s0 = jnp.zeros((Bw, Hw, Kw, Kw))
    scan_f = jax.jit(lambda: linear_attention_scan(
        qw, kw, vw, lw, s0, mode="rwkv", u=uw)[0])
    chunk_f = jax.jit(lambda: linear_attention_chunked(
        qw, kw, vw, lw, s0, mode="rwkv", u=uw, chunk=64)[0])
    t_scan = time_call(lambda: np.asarray(scan_f()))
    t_chunk = time_call(lambda: np.asarray(chunk_f()))
    # per-token state round-trip the VMEM-resident kernel avoids
    state_traffic = Bw * Hw * Kw * Kw * 4 * 2 * Tw
    results["wkv_scan"] = {
        "us_naive_scan": t_scan, "us_chunked": t_chunk,
        "hbm_state_bytes_avoided": state_traffic,
        "tpu_memory_term_saved_s": state_traffic / 819e9,
    }
    emit_csv_row("kernel/wkv_chunked", t_chunk,
                 f"naive_scan={t_scan:.0f}us;"
                 f"state_traffic_avoided={state_traffic/1e6:.0f}MB")

    bench_paged_decode(jax.random.fold_in(key, 11), results)
    bench_batched_prefill(jax.random.fold_in(key, 12), results)

    save_result("kernels", results)
    return results


if __name__ == "__main__":
    run()
