"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode (Python — timings are NOT
hardware-representative); what we measure here is the XLA *fused chunked*
Gatekeeper loss / entropy path against the naive materialize-[T,V] path,
plus derived roofline units (bytes avoided) for the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.deferral import negative_entropy
from repro.core.gatekeeper import GatekeeperConfig, gatekeeper_loss
from repro.launch.steps import chunked_gatekeeper_loss, fused_confidence

from benchmarks.common import emit_csv_row, save_result, time_call

GK = GatekeeperConfig(alpha=0.3)


def run():
    key = jax.random.PRNGKey(0)
    results = {}
    # moderate CPU-feasible proxy of the V=163840 regime
    B, S, d, V = 8, 128, 256, 16384
    x = jax.random.normal(key, (B, S, d), jnp.float32)
    table = jax.random.normal(jax.random.fold_in(key, 1), (V, d))
    tgt = jax.random.randint(key, (B, S), 0, V)

    naive = jax.jit(lambda x, t, y: gatekeeper_loss(
        jnp.einsum("bsd,vd->bsv", x, t), y, GK)[0])
    fused = jax.jit(lambda x, t, y: chunked_gatekeeper_loss(
        x, t, y, GK, n_chunks=16)[0])
    t_naive = time_call(lambda: float(naive(x, table, tgt)))
    t_fused = time_call(lambda: float(fused(x, table, tgt)))
    # bytes the fused path avoids writing+reading in HBM (fp32 logits x3)
    avoided = B * S * V * 4 * 3
    results["gatekeeper_loss"] = {
        "us_naive": t_naive, "us_fused": t_fused,
        "hbm_bytes_avoided": avoided,
        "tpu_memory_term_saved_s": avoided / 819e9,
    }
    emit_csv_row("kernel/gatekeeper_fused", t_fused,
                 f"naive={t_naive:.0f}us;avoided={avoided/1e6:.0f}MB")

    # deferral entropy at decode: [128, 16384]
    logits = jax.random.normal(key, (128, V))
    naive_e = jax.jit(lambda l: negative_entropy(l))
    xf = jax.random.normal(key, (128, d))
    fused_e = jax.jit(lambda x, t: fused_confidence(x, t, n_chunks=8)[0])
    t_naive = time_call(lambda: np.asarray(naive_e(logits)))
    t_fused = time_call(lambda: np.asarray(fused_e(xf, table)))
    results["deferral_entropy"] = {"us_naive": t_naive, "us_fused": t_fused}
    emit_csv_row("kernel/deferral_entropy", t_fused,
                 f"naive_from_logits={t_naive:.0f}us")

    # WKV recurrence: naive per-token scan vs chunk-parallel (the Pallas
    # kernel's algorithm; interpret-mode timing is not meaningful, so we
    # time the XLA chunked path it mirrors and report the state-traffic
    # the VMEM-resident kernel avoids)
    from repro.models.ssm import (linear_attention_chunked,
                                  linear_attention_scan)
    Bw, Tw, Hw, Kw = 4, 256, 4, 64
    kk = jax.random.split(jax.random.fold_in(key, 7), 6)
    qw = jax.random.normal(kk[0], (Bw, Tw, Hw, Kw)) * 0.5
    kw = jax.random.normal(kk[1], (Bw, Tw, Hw, Kw)) * 0.5
    vw = jax.random.normal(kk[2], (Bw, Tw, Hw, Kw)) * 0.5
    lw = -jax.random.uniform(kk[3], (Bw, Tw, Hw, Kw), minval=0.05, maxval=1.0)
    uw = jax.random.normal(kk[4], (Hw, Kw)) * 0.3
    s0 = jnp.zeros((Bw, Hw, Kw, Kw))
    scan_f = jax.jit(lambda: linear_attention_scan(
        qw, kw, vw, lw, s0, mode="rwkv", u=uw)[0])
    chunk_f = jax.jit(lambda: linear_attention_chunked(
        qw, kw, vw, lw, s0, mode="rwkv", u=uw, chunk=64)[0])
    t_scan = time_call(lambda: np.asarray(scan_f()))
    t_chunk = time_call(lambda: np.asarray(chunk_f()))
    # per-token state round-trip the VMEM-resident kernel avoids
    state_traffic = Bw * Hw * Kw * Kw * 4 * 2 * Tw
    results["wkv_scan"] = {
        "us_naive_scan": t_scan, "us_chunked": t_chunk,
        "hbm_state_bytes_avoided": state_traffic,
        "tpu_memory_term_saved_s": state_traffic / 819e9,
    }
    emit_csv_row("kernel/wkv_chunked", t_chunk,
                 f"naive_scan={t_scan:.0f}us;"
                 f"state_traffic_avoided={state_traffic/1e6:.0f}MB")

    save_result("kernels", results)
    return results


if __name__ == "__main__":
    run()
