"""Paper Fig. 4/5 (+ App. Figs 9-12): image-classification cascade, alpha
sweep. CPU-scale instantiation (see DESIGN.md §7): synthetic easy/parity
task; M_S = (64,64) MLP on 3k samples trained to interpolation (overconfident
on its test errors — the CIFAR-CNN regime); M_L = (256,256) MLP on 25k
samples (learns the hard tier exactly).

Stage-2 note (adaptation, recorded in EXPERIMENTS.md): the paper fine-tunes
on the training split; its models do not interpolate that split. At our
scale M_S reaches 100% train accuracy, which would starve eq. (3) of
incorrect examples — so Gatekeeper fine-tuning uses a HELD-OUT calibration
split, the scale-equivalent of "training data the model still gets wrong".

Expected reproduction (paper trends):
  alpha ↓  =>  s_o ↓ (separation up), s_d ↑, AUROC ↑, acc(M_S) ↓/flat.
"""
from __future__ import annotations

import time

import jax

from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_classification
from repro.models.classifier import (MLPClassifierConfig, classifier_forward,
                                     init_classifier)
from repro.training import optim
from repro.training.loop import evaluate_classifier, make_train_step, train

from benchmarks.common import emit_csv_row, save_result

ALPHAS = (0.05, 0.2, 0.5, 0.8, 0.95)


def _fit(cfg, data, seed, steps, loss_kind="ce", gk=None, init=None,
         lr=3e-3):
    params = init if init is not None else init_classifier(
        cfg, jax.random.PRNGKey(seed))
    apply_fn = lambda p, b: classifier_forward(p, cfg, b["inputs"])
    it = BatchIterator({"inputs": data.x, "targets": data.y}, 256,
                       key=jax.random.PRNGKey(seed))
    step = make_train_step(apply_fn, optim.AdamWConfig(lr=lr,
                                                       total_steps=steps),
                           loss_kind=loss_kind, gk_cfg=gk)
    return train(params, step, it.forever(), steps, log_every=10**9).params


def run(n_train=3000, n_large=25000, n_cal=4000, n_test=3000,
        steps=2500, gk_steps=3000, seed=0):
    key = jax.random.PRNGKey(seed)
    tr_s = make_classification(key, n_train, n_classes=8, hard_frac=0.45)
    tr_l = make_classification(jax.random.fold_in(key, 5), n_large,
                               n_classes=8, hard_frac=0.45)
    cal = make_classification(jax.random.fold_in(key, 7), n_cal, 8,
                              hard_frac=0.45)
    te = make_classification(jax.random.fold_in(key, 1), n_test, 8,
                             hard_frac=0.45)
    d_in = tr_s.x.shape[1]
    s_cfg = MLPClassifierConfig(d_in=d_in, n_classes=8, hidden=(64, 64))
    l_cfg = MLPClassifierConfig(d_in=d_in, n_classes=8, hidden=(256, 256))

    t0 = time.perf_counter()
    small = _fit(s_cfg, tr_s, 1, steps)
    large = _fit(l_cfg, tr_l, 2, max(steps, 4000))
    _, _, lcorr = evaluate_classifier(
        lambda p, x: classifier_forward(p, l_cfg, x), large, te.x, te.y)

    def metrics_of(params):
        _, conf, corr = evaluate_classifier(
            lambda p, x: classifier_forward(p, s_cfg, x), params, te.x, te.y)
        return summarize_deferral(conf, corr, lcorr)

    rows = {"baseline": metrics_of(small)}
    for a in ALPHAS:
        tuned = _fit(s_cfg, cal, 3, gk_steps, loss_kind="gatekeeper",
                     gk=GatekeeperConfig(alpha=a), init=small, lr=5e-3)
        rows[f"alpha={a}"] = metrics_of(tuned)
    elapsed = time.perf_counter() - t0

    payload = {k: {m: v[m] for m in ("s_d", "s_o", "auroc", "acc_small",
                                     "acc_large")}
               for k, v in rows.items()}
    save_result("fig4_classification", payload)
    for k, v in payload.items():
        emit_csv_row(f"fig4/{k}",
                     elapsed / len(rows) * 1e6,
                     f"s_d={v['s_d']:.3f};s_o={v['s_o']:.3f};"
                     f"auroc={v['auroc']:.3f};acc={v['acc_small']:.3f}")
    return payload


if __name__ == "__main__":
    run()
