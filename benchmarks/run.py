# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper figure/table:

  fig4   — image-classification alpha sweep (paper Fig. 4/5, Figs 9-12)
  fig6   — LM alpha sweep + prompting baselines (paper Fig. 6, App. B.2)
  fig7   — VLM classification + captioning factuality (paper Fig. 7)
  rawat  — static vs dynamic partition (paper §2 related work)
  soft   — hard-label vs M_L-soft-target Gatekeeper (paper §3.2 ablation)
  kernel — fused loss/entropy kernels vs naive paths
  serving— static vs continuous-batching cascade engines (tok/s, latency)

`python -m benchmarks.run [--only fig4,...] [--fast]`
"""
import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig6,fig7,rawat,soft,kernel,"
                         "serving")
    ap.add_argument("--fast", action="store_true",
                    help="reduced budgets (CI smoke)")
    args = ap.parse_args()

    from benchmarks import (bench_ablation_soft, bench_fig4_classification,
                            bench_fig6_lm, bench_fig7_vlm, bench_kernels,
                            bench_serving, bench_static_partition)

    fast_kw = {
        "fig4": dict(n_train=4000, n_test=1500, steps=200, gk_steps=150),
        "fig6": dict(n_train=4000, n_test=1200, steps=250, gk_steps=150),
        "fig7": dict(n_train=3000, n_test=1000, steps=200, gk_steps=120),
        "rawat": dict(n_train=4000, n_test=1500, steps=200, ft_steps=150),
        "soft": dict(n_train=3000, n_test=1500, steps=300, gk_steps=200),
        "serving": dict(n_requests=16, max_new=12, slots=4),
    }
    suites = {
        "fig4": lambda: bench_fig4_classification.run(
            **(fast_kw["fig4"] if args.fast else {})),
        "fig6": lambda: bench_fig6_lm.run(
            **(fast_kw["fig6"] if args.fast else {})),
        "fig7": lambda: bench_fig7_vlm.run(
            **(fast_kw["fig7"] if args.fast else {})),
        "rawat": lambda: bench_static_partition.run(
            **(fast_kw["rawat"] if args.fast else {})),
        "soft": lambda: bench_ablation_soft.run(
            **(fast_kw["soft"] if args.fast else {})),
        "kernel": bench_kernels.run,
        "serving": lambda: bench_serving.run(
            **(fast_kw["serving"] if args.fast else {})),
    }
    only = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    failed = []
    for name in only:
        t0 = time.time()
        try:
            suites[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
