"""§Perf hillclimb driver — labeled hypothesis→change→measure iterations.

Three pairs chosen from the §Roofline baseline table (see EXPERIMENTS.md):

  qwen     = qwen1.5-32b  × prefill_32k  (worst useful-FLOPs fraction, 0.093;
             memory-dominant: S^2 attention HBM traffic + 40-head MHA that
             does not divide the 16-way model axis)
  kimi     = kimi-k2-1t-a32b × decode_32k (most collective-bound meaningful
             pair; MoE all-to-all + V=163,840 fused entropy deferral — the
             paper's serving path)
  llama    = llama3-405b  × train_4k     (most representative of the paper's
             technique: the Gatekeeper fine-tune step at the largest dense
             scale; memory-dominant, does not fit HBM without remat+ZeRO)

Each variant is a named (remat, rule_overrides, cfg_overrides) tuple.
Results are appended to benchmarks/results/hillclimb.jsonl with the label,
so EXPERIMENTS.md §Perf can cite exact before/after numbers.

    PYTHONPATH=src python -m benchmarks.hillclimb --pair qwen --variant baseline
    PYTHONPATH=src python -m benchmarks.hillclimb --pair qwen --list
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

PAIRS = {
    "qwen":  ("qwen1.5-32b", "prefill_32k"),
    "kimi":  ("kimi-k2-1t-a32b", "decode_32k"),
    "llama": ("llama3-405b", "train_4k"),
}

# label -> dict(remat=..., rules=..., cfg=..., multi_pod=...)
VARIANTS = {
    "qwen": {
        "baseline": {},
        # H1: [B,KV,g,S,S] f32 score materialization is the HBM-traffic
        # wall; chunked/online-softmax attention removes the S^2 resident
        # tensor (flash-attention schedule at the XLA level).
        "chunked-attn": {"cfg": {"attn_chunk": 1024}},
        # H2: 40 heads % 16 != 0 leaves the model axis idle through the
        # whole attention path; sequence-parallel attention shards S=32768
        # over the model axis instead (context parallelism).
        "seq-parallel": {"rules": {"seq": ("model",)}},
        # H3 = H1 + H2 composed.
        "chunked+seqpar": {"cfg": {"attn_chunk": 1024},
                           "rules": {"seq": ("model",)}},
        # H4: prefill unembedded ALL 32k positions against V=152k and then
        # sliced [-1] — 2·B·S·d·V useless flops. Unembed the last position
        # only (adopted as the serving default after this measurement).
        "chunked+seqpar+lastlogit": {"cfg": {"attn_chunk": 1024},
                                     "rules": {"seq": ("model",)}},
        # H5 (refuted): constrain K/V seq-replicated ("gather x once") —
        # GSPMD materializes the constraint as the same all-gather, so
        # bytes were unchanged; kept for the log.
    },
    "kimi": {
        "baseline": {},
        # H1: at decode, 128 tokens (1.8 MB) route to experts whose weights
        # are 2 TB; the ZeRO-3 default (expert_embed -> data) forces a
        # per-layer expert-weight all-gather over the data axis. Shard the
        # expert FFN dim over data instead and GATHER THE TOKENS: weights
        # never move, partial results psum.
        "gather-tokens": {"rules": {"expert_embed": (), "expert_ffn": ("data",)}},
        # H2: kv_heads=8 < 16 leaves the model axis idle for the KV cache;
        # shard cache_seq over model too (decode reads the whole cache
        # every step — that's the memory term).
        "cache-seq-model": {"rules": {"cache_seq": ("data", "model")}},
        # H3 composed.
        "gather+cache": {"rules": {"expert_embed": (), "expert_ffn": ("data",),
                                   "cache_seq": ("data", "model")}},
        # H4: the fused entropy (eq. 8) all-gathers the unembed table's
        # FSDP d-shard per vocab chunk; shard x_final's d instead ->
        # partial [T, Vc] logits psum (5 MB vs 270 MB per chunk).
        "gather+cache+psum": {"rules": {"expert_embed": (),
                                        "expert_ffn": ("data",),
                                        "cache_seq": ("data", "model"),
                                        "unembed_d": ("data",)}},
    },
    "llama": {
        "baseline": {},
        # H1: no remat saves every per-layer activation for the backward
        # pass (126 layers x ~2 GB/dev) — full remat trades ~33% more
        # FLOPs for O(layers) less HBM-resident bytes.
        "remat-full": {"remat": "full"},
        # H2: remat dots-only (keep cheap elementwise, recompute matmuls'
        # inputs) — the usual sweet spot.
        "remat-dots": {"remat": "dots"},
        # H3: ZeRO-1: shard AdamW mu/nu over BOTH mesh axes (embed already
        # takes data; let opt state take model too via the ffn/heads dims
        # it naturally has). Implemented as sharding the vocab/ffn dims of
        # the opt state — rule override applies to the whole state tree.
        "remat+zero": {"remat": "full",
                       "rules": {"embed": ("data", "model")}},
        # H4: gradient accumulation — activations scale with the
        # microbatch, composing with remat (peak-memory knob #2).
        "remat+micro16": {"remat": "full", "cfg": {"microbatches": 16}},
        # H5: ZeRO + remat + microbatching together.
        "remat+zero+micro16": {"remat": "full",
                               "cfg": {"microbatches": 16},
                               "rules": {"embed": ("data", "model")}},
        # H6 (refuted): params FSDP-sharded over BOTH axes — SPMD hits
        # "involuntary full rematerialization" on the scan's weight-slice
        # reshard (b/433785288); depth scaling goes non-monotonic.
        "remat+zero+micro16/2": {"remat": "full",
                                 "cfg": {"microbatches": 16},
                                 "rules": {"embed": ("data", "model")}},
        # H6': ZeRO-1 instead — params keep the TP layout; only AdamW
        # mu/nu shard over extra axes. The update (outside the layer scan)
        # reduce-scatters grads into the opt shard; no scan resharding.
        "multipod-zero1": {"remat": "full", "multi_pod": True,
                           "cfg": {"microbatches": 32},
                           "opt_rules": {"embed": ("pod", "data")}},
    },
}


def run(pair: str, variant: str, out: str):
    from repro.launch.dryrun import lower_combo
    arch, shape = PAIRS[pair]
    v = VARIANTS[pair][variant]
    label = f"{pair}:{variant}"
    res = lower_combo(arch, shape, v.get("multi_pod", False),
                      remat=v.get("remat", "none"),
                      rule_overrides=v.get("rules"),
                      cfg_overrides=v.get("cfg"),
                      opt_rule_overrides=v.get("opt_rules"),
                      label=label, verbose=True)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "a") as f:
        f.write(json.dumps(res) + "\n")
    print(f"[hillclimb] {label}: compute={res['compute_s']:.4g}s "
          f"memory={res['memory_s']:.4g}s collective={res['collective_s']:.4g}s "
          f"dominant={res['dominant']} peak={res['peak_memory_bytes']/2**30:.1f}GiB "
          f"fits={res['fits_hbm']}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True, choices=list(PAIRS))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/hillclimb.jsonl")
    args = ap.parse_args()
    if args.list or args.variant is None:
        for k, v in VARIANTS[args.pair].items():
            print(f"{k}: {v}")
        return
    run(args.pair, args.variant, args.out)


if __name__ == "__main__":
    main()
