"""Estimate the TPU-adjusted peak for hillclimb variants.

The CPU backend has no native bf16 dot, so XLA materializes f32 copies of
bf16 weights/caches (convert fusions). A TPU build feeds bf16 straight to
the MXU — those temps don't exist there. This script sums the outputs of
large convert-style ops and reports peak_measured - conversion_copies.

CAVEAT: the sum counts every conversion buffer, not just those live at
the peak point, so it is an UPPER bound on the conversion footprint and
the adjusted peak is a LOWER bound (it can go negative when per-layer
conversions that never coexist are all counted — qwen/llama). It is tight
only when the conversions are loop-carried top-level tensors live for the
whole while-loop (kimi decode: the 3x4.9 GiB expert-weight stacks + 2x3.3
GiB cache copies). Pair it with the analytic state accounting in
EXPERIMENTS.md §Perf; the defensible per-variant numbers quoted there are
kimi ≈ 12-13 GiB (args+out+working set) and llama ≈ 12-14 GiB
(state 7.9 GiB + micro32 remat residuals ≈ 4 GiB).

    PYTHONPATH=src python -m benchmarks.tpu_adjusted_peak
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import re


def conversion_bytes(hlo: str, min_bytes: float = 64e6) -> float:
    """Sum output bytes of f32 tensors produced by convert/copy fusions of
    bf16 inputs (the CPU-backend artifact)."""
    total = 0.0
    pat = re.compile(r"= f32\[([\d,]+)\][^=]*"
                     r"(wrapped_convert|convert\(|convert_|copy_convert)")
    for line in hlo.splitlines():
        m = pat.search(line)
        if not m:
            continue
        n = 1
        for x in m.group(1).split(","):
            if x:
                n *= int(x)
        b = n * 4
        if b >= min_bytes:
            total += b
    return total


def main():
    from repro.configs import SHAPES, get_config
    from repro.launch.specs import adapt_for_shape
    from repro.launch.dryrun import _lower_for
    from repro.launch.mesh import make_production_mesh, make_context
    from repro.sharding import rules_dict
    from benchmarks.hillclimb import PAIRS, VARIANTS

    finals = [("kimi", "gather+cache+psum"), ("qwen", "chunked+seqpar+lastlogit"),
              ("llama", "multipod-zero1")]
    out = {}
    for pair, variant in finals:
        arch, shape_name = PAIRS[pair]
        v = VARIANTS[pair][variant]
        shape = SHAPES[shape_name]
        cfg = adapt_for_shape(get_config(arch), shape)
        if v.get("remat"):
            cfg = cfg.replace(remat=v["remat"])
        if v.get("cfg"):
            cfg = cfg.replace(**v["cfg"])
        rules = rules_dict(v.get("rules") or {})
        opt_rules = (rules_dict({**(v.get("rules") or {}), **v["opt_rules"]})
                     if v.get("opt_rules") else None)
        mesh = make_production_mesh(multi_pod=v.get("multi_pod", False))
        ctx = dataclasses.replace(make_context(mesh), rules=rules)
        compiled = _lower_for(cfg, shape, mesh, ctx, rules=rules,
                              opt_rules=opt_rules).compile()
        m = compiled.memory_analysis()
        peak = (m.argument_size_in_bytes + m.output_size_in_bytes
                + m.temp_size_in_bytes - m.alias_size_in_bytes)
        conv = conversion_bytes(compiled.as_text())
        adj = peak - conv
        out[f"{pair}:{variant}"] = {
            "peak_gib": peak / 2**30, "conversion_gib": conv / 2**30,
            "tpu_adjusted_peak_gib": adj / 2**30,
            "fits_16gib_adjusted": bool(adj <= 16 * 2**30),
        }
        print(f"{pair}:{variant}: peak={peak/2**30:.1f} GiB, "
              f"f32-conversion copies={conv/2**30:.1f} GiB, "
              f"TPU-adjusted={adj/2**30:.1f} GiB "
              f"fits={adj <= 16*2**30}")
    with open("benchmarks/results/tpu_adjusted_peak.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
