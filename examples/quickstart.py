"""Quickstart: build a Gatekeeper cascade in ~60 lines.

Trains a weak M_S and a strong M_L on the synthetic classification task,
confidence-tunes M_S with the Gatekeeper loss (paper eq. 1-3), calibrates a
deferral threshold, and reports the joint accuracy / compute trade-off.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import Cascade, GatekeeperConfig, summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_classification
from repro.models.classifier import (MLPClassifierConfig, classifier_forward,
                                     init_classifier)
from repro.training import optim
from repro.training.loop import evaluate_classifier, make_train_step, train


def fit(cfg, data, steps, *, loss="ce", alpha=None, init=None, lr=3e-3,
        seed=0):
    params = init if init is not None else init_classifier(
        cfg, jax.random.PRNGKey(seed))
    it = BatchIterator({"inputs": data.x, "targets": data.y}, 256,
                       key=jax.random.PRNGKey(seed))
    step = make_train_step(
        lambda p, b: classifier_forward(p, cfg, b["inputs"]),
        optim.AdamWConfig(lr=lr, total_steps=steps), loss_kind=loss,
        gk_cfg=GatekeeperConfig(alpha=alpha) if alpha else None)
    return train(params, step, it.forever(), steps, log_every=10**9).params


def main():
    key = jax.random.PRNGKey(0)
    tr_s = make_classification(key, 2000, n_classes=8)
    tr_l = make_classification(jax.random.fold_in(key, 5), 15000, 8)
    cal = make_classification(jax.random.fold_in(key, 7), 3000, 8)
    te = make_classification(jax.random.fold_in(key, 1), 3000, 8)

    s_cfg = MLPClassifierConfig(d_in=tr_s.x.shape[1], n_classes=8,
                                hidden=(64, 64))
    l_cfg = MLPClassifierConfig(d_in=tr_s.x.shape[1], n_classes=8,
                                hidden=(256, 256))
    print("Stage 1: standard training ...")
    small = fit(s_cfg, tr_s, 1500)
    large = fit(l_cfg, tr_l, 2500, seed=1)

    print("Stage 2: Gatekeeper confidence tuning (alpha=0.05) ...")
    tuned = fit(s_cfg, cal, 1500, loss="gatekeeper", alpha=0.05, init=small,
                lr=5e-3)

    _, _, lcorr = evaluate_classifier(
        lambda p, x: classifier_forward(p, l_cfg, x), large, te.x, te.y)
    for name, params in [("baseline", small), ("gatekeeper", tuned)]:
        _, conf, corr = evaluate_classifier(
            lambda p, x: classifier_forward(p, s_cfg, x), params, te.x, te.y)
        m = summarize_deferral(conf, corr, lcorr)
        print(f"  {name:10s}: acc(M_S)={m['acc_small']:.3f} "
              f"s_d={m['s_d']:.3f} s_o={m['s_o']:.3f} "
              f"auroc={m['auroc']:.3f}")

    print("Stage 3: thresholded cascade at a 30% deferral budget ...")
    cascade = Cascade(
        small_apply=lambda p, x: classifier_forward(p, s_cfg, x),
        large_apply=lambda p, x: classifier_forward(p, l_cfg, x),
        small_params=tuned, large_params=large, cost_small=0.2)
    cascade.calibrate_tau(jnp.asarray(te.x[:1000]), deferral_ratio=0.3)
    res = cascade.predict_sparse(jnp.asarray(te.x[1000:]))
    acc = (res.predictions == te.y[1000:]).mean()
    print(f"  joint accuracy={acc:.3f} at deferral={res.deferral_ratio:.2f} "
          f"compute={res.compute_cost:.2f}x of always-large")


if __name__ == "__main__":
    main()
