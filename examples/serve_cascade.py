"""End-to-end serving example: batched requests through the cascade engine
with KV-cache decode and per-request Gatekeeper deferral (paper Fig. 1
deployment topology).

    PYTHONPATH=src python examples/serve_cascade.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.data.synthetic import make_lm_stream
from repro.models import transformer as tfm
from repro.serving.engine import CascadeEngine, ModelRunner


def main():
    key = jax.random.PRNGKey(0)
    s_cfg = reduced(get_config("qwen1.5-4b"))
    l_cfg = s_cfg.replace(name="qwen-large-proxy", n_layers=4,
                          d_model=2 * s_cfg.d_model, n_heads=8,
                          d_ff=2 * s_cfg.d_ff)
    print(f"M_S: {s_cfg.name} ({s_cfg.n_layers}L x {s_cfg.d_model})  "
          f"M_L: {l_cfg.name} ({l_cfg.n_layers}L x {l_cfg.d_model})")

    small = ModelRunner(s_cfg, tfm.init_params(s_cfg, key))
    large = ModelRunner(l_cfg, tfm.init_params(l_cfg,
                                               jax.random.fold_in(key, 1)))

    prompt_len, max_new = 16, 8
    prompts = make_lm_stream(jax.random.fold_in(key, 2), 64, prompt_len,
                             s_cfg.vocab_size)
    cal, live = prompts[:32], prompts[32:]

    engine = CascadeEngine(small, large, cost_small=0.2)
    for target in (0.1, 0.3, 0.6):
        tau = engine.calibrate(cal, prompt_len, max_new, target)
        res = engine.serve(live, prompt_len, max_new)
        print(f"target deferral={target:.1f}: tau={tau:+.3f} "
              f"realized={res.deferral_ratio:.2f} "
              f"compute={res.compute_cost:.2f}x "
              f"mean g_NENT={res.confidence.mean():+.3f}")
    print("sample continuations (first 3):")
    for row in res.tokens[:3]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
