"""End-to-end driver: train a ~100M-parameter decoder for a few hundred
steps (Stage 1 CE + Stage 2 Gatekeeper) on the synthetic LM stream.

This is the assignment's "train ~100M model for a few hundred steps" driver;
on CPU it is slow but real. Reduce --steps for a quick look.

    PYTHONPATH=src python examples/train_100m.py --stage1-steps 300 \
        --stage2-steps 100
"""
import argparse
import sys

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage1-steps", type=int, default=300)
    ap.add_argument("--stage2-steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    argv = ["--preset", "100m", "--task", "stream",
            "--stage1-steps", str(args.stage1_steps),
            "--stage2-steps", str(args.stage2_steps),
            "--batch", str(args.batch), "--seq-len", str(args.seq_len),
            "--n-train", "512", "--log-every", "10",
            "--ckpt", "/tmp/repro_100m_ckpt"]
    sys.argv = ["train"] + argv
    train_launch.main()


if __name__ == "__main__":
    main()
