"""Token-level Gatekeeper on decoder LMs (paper §4.2 shape).

Trains a 1-layer M_S and a 4-layer M_L on the synthetic closed-form QA task,
fine-tunes M_S with the token-level Gatekeeper loss (eqs. 4-5), and compares
the g_NENT deferral signal (eq. 8) before/after, including the App. B.2
prompting baselines.

    PYTHONPATH=src python examples/lm_cascade.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.core.baselines import PromptingBaseline
from repro.core.deferral import sequence_negative_entropy
from repro.core.gatekeeper import GatekeeperConfig
from repro.core.metrics import summarize_deferral
from repro.data.pipeline import BatchIterator
from repro.data.synthetic import make_qa
from repro.models import transformer as tfm
from repro.sharding import ParallelContext
from repro.training import optim
from repro.training.loop import make_train_step, train

CTX = ParallelContext()


def mk_cfg(name, layers, d):
    return ModelConfig(name=name, family="dense", n_layers=layers, d_model=d,
                       n_heads=4, n_kv_heads=4, head_dim=d // 4, d_ff=4 * d,
                       vocab_size=32, tie_embeddings=True)


def fit(cfg, data, steps, *, loss="ce", alpha=None, init=None, lr=3e-3,
        seed=0):
    params = init if init is not None else tfm.init_params(
        cfg, jax.random.PRNGKey(seed))
    it = BatchIterator({"inputs": data.inputs, "targets": data.targets,
                        "loss_mask": data.loss_mask}, 256,
                       key=jax.random.PRNGKey(seed))
    step = make_train_step(
        lambda p, b: tfm.forward(p, cfg, b["inputs"], CTX),
        optim.AdamWConfig(lr=lr, total_steps=steps), loss_kind=loss,
        gk_cfg=GatekeeperConfig(alpha=alpha) if alpha else None)
    return train(params, step, it.forever(), steps, log_every=10**9).params


def answer_eval(cfg, params, data):
    logits = tfm.forward(params, cfg, jnp.asarray(data.inputs), CTX)
    pos = data.answer_pos - 1
    preds = np.asarray(jnp.argmax(logits[:, pos, :], -1))
    correct = (preds == data.targets[:, pos]).astype(float)
    conf = np.asarray(sequence_negative_entropy(
        logits, jnp.asarray(data.loss_mask)))
    return conf, correct


def main():
    key = jax.random.PRNGKey(0)
    tr = make_qa(key, 8000)
    cal = make_qa(jax.random.fold_in(key, 7), 4000)
    te = make_qa(jax.random.fold_in(key, 1), 3000)
    s_cfg, l_cfg = mk_cfg("small", 1, 64), mk_cfg("large", 4, 192)

    print("training M_S / M_L on closed-form QA ...")
    small = fit(s_cfg, tr, 400)
    large = fit(l_cfg, tr, 600, seed=1)
    _, lcorr = answer_eval(l_cfg, large, te)
    print(f"  acc(M_L) = {lcorr.mean():.3f}")

    conf, corr = answer_eval(s_cfg, small, te)
    base = summarize_deferral(conf, corr, lcorr)
    print(f"  baseline: acc={base['acc_small']:.3f} s_d={base['s_d']:.3f} "
          f"auroc={base['auroc']:.3f}")

    for kind in ("reduce_confidence", "answer_n"):
        pb = PromptingBaseline(kind)
        logits = tfm.forward(small, s_cfg,
                             pb.modify_inputs(jnp.asarray(te.inputs)), CTX)
        pos = te.answer_pos - 1
        preds = np.asarray(jnp.argmax(logits[:, pos, :], -1))
        c = (preds == te.targets[:, pos]).astype(float)
        conf_pb = np.asarray(pb.confidence_from_logits(logits[:, pos, :]))
        m = summarize_deferral(conf_pb, c, lcorr)
        print(f"  prompt '{kind}': acc={m['acc_small']:.3f} "
              f"s_d={m['s_d']:.3f} auroc={m['auroc']:.3f}")

    print("Gatekeeper token-level fine-tune (alpha=0.1) ...")
    tuned = fit(s_cfg, cal, 300, loss="gatekeeper", alpha=0.1, init=small,
                lr=1e-3)
    conf, corr = answer_eval(s_cfg, tuned, te)
    gk = summarize_deferral(conf, corr, lcorr)
    print(f"  gatekeeper: acc={gk['acc_small']:.3f} s_d={gk['s_d']:.3f} "
          f"auroc={gk['auroc']:.3f}")


if __name__ == "__main__":
    main()
